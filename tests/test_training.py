"""Training substrate: optimizer, schedules, microbatch accumulation
equivalence, gradient compression numerics, loss-goes-down."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as tfm
from repro.training import optimizer as opt
from repro.training.grad_compression import dequantize_int8, quantize_int8
from repro.training.train_loop import TrainConfig, _accumulate_grads, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), vocab=256)
    params = tfm.init_params(jax.random.key(0), cfg)
    return cfg, params


def test_lr_schedule_shape():
    ocfg = opt.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(opt.lr_schedule(ocfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9  # mid-warmup
    assert abs(lrs[2] - 1e-3) < 1e-9  # peak
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-4) < 1e-8  # min_lr_frac * lr


def test_loss_decreases(tiny):
    cfg, params = tiny
    tcfg = TrainConfig(opt=opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
    step_fn = make_train_step(cfg, tcfg, None, None)
    state = {"params": params, "opt": opt.init_opt_state(params, tcfg.opt)}
    losses = []
    for s in range(30):
        state, metrics = step_fn(state, pipe.batch(s))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25, losses
    assert all(np.isfinite(losses))


def test_microbatch_accumulation_equivalence(tiny):
    cfg, _ = tiny
    cfg = dataclasses.replace(cfg, dtype="float32")  # tight comparison
    params = tfm.init_params(jax.random.key(0), cfg)
    loss_fn = tfm.make_loss_fn(cfg)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
    batch = pipe.batch(0)
    l1, g1 = _accumulate_grads(loss_fn, params, batch, 1)
    l4, g4 = _accumulate_grads(loss_fn, params, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-3, atol=1e-5
        )


def test_grad_clipping_bounds_update():
    ocfg = opt.OptConfig(lr=1.0, warmup_steps=0, total_steps=10, clip_norm=1.0,
                         weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    st = opt.init_opt_state(params, ocfg)
    huge = {"w": jnp.full((4,), 1e6)}
    new_p, new_st, metrics = opt.adamw_update(huge, st, params, ocfg)
    assert float(metrics["grad_norm"]) > 1e5
    # clipped: the effective first moment is bounded by clip_norm
    assert np.isfinite(np.asarray(new_p["w"])).all()


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale)
    err = np.abs(np.asarray(deq - g)).max()
    assert err <= float(scale) / 2 + 1e-9  # half-ulp of the int8 grid
    # error feedback closes the loop: residual + deq == original
    np.testing.assert_allclose(
        np.asarray(deq + (g - deq)), np.asarray(g), rtol=0, atol=0
    )


def test_opt_state_specs_structure(tiny):
    cfg, params = tiny
    pspecs = tfm.param_specs(cfg)
    ospecs = opt.opt_state_specs(pspecs)
    ostate = opt.init_opt_state(params, opt.OptConfig())
    jax.tree.map(lambda a, b: None, ostate["m"], ospecs["m"])  # structure match
