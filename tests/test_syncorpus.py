"""Determinism and structure of the synthetic scale corpus: document i is
a pure function of (seed, i) — identical across batch sizes and access
order — and the generated text carries the entity/keyword structure the
ingest analyzer stack extracts."""

from __future__ import annotations

import numpy as np
import pytest

from repro.data.syncorpus import SynCorpus, SynCorpusConfig
from repro.ingest.entities import extract_entity_spans

CFG = SynCorpusConfig(
    n_docs=512, n_topics=16, n_entities=48, n_queries=32, seed=3
)


@pytest.fixture(scope="module")
def gen():
    return SynCorpus(CFG)


def test_same_seed_identical_docs_across_batch_sizes(gen):
    """The determinism contract: streaming in batches of 7 and of 64
    yields byte-identical documents, equal to direct random access."""
    via_7 = [d for b in gen.doc_batches(7) for d in b]
    via_64 = [d for b in gen.doc_batches(64) for d in b]
    assert len(via_7) == len(via_64) == CFG.n_docs
    for i in (0, 1, 13, 255, CFG.n_docs - 1):
        assert via_7[i] == via_64[i] == gen.doc(i)
    # a fresh generator instance reproduces the stream exactly
    again = SynCorpus(CFG)
    assert [d.text for d in via_7[:50]] == [
        again.doc(i).text for i in range(50)
    ]


def test_access_order_independence(gen):
    backwards = [gen.doc(i).text for i in reversed(range(64))][::-1]
    forwards = [gen.doc(i).text for i in range(64)]
    assert backwards == forwards


def test_different_seeds_differ(gen):
    import dataclasses

    other = SynCorpus(dataclasses.replace(CFG, seed=CFG.seed + 1))
    assert gen.doc(0).text != other.doc(0).text


def test_batch_windows_and_bounds(gen):
    docs = [d for b in gen.doc_batches(100, start=30, stop=140) for d in b]
    assert [d.doc_id for d in docs] == list(range(30, 140))
    with pytest.raises(IndexError):
        gen.doc(CFG.n_docs)
    with pytest.raises(IndexError):
        gen.doc(-1)


def test_entities_are_extractable_and_topic_scoped(gen):
    """Entity mentions sit mid-sentence as multi-word capitalized spans, so
    the rule-based extractor recovers them; all belong to the doc's topic
    pool (topic affinity makes co-occurrence triplets cluster)."""
    for i in range(0, 64, 7):
        doc = gen.doc(i)
        spans = set(extract_entity_spans(doc.text))
        for ent in doc.entities:
            assert ent in spans, f"doc {i}: {ent!r} not extracted"
        home = {
            gen.entity_names[e]
            for e in gen._topic_entities(doc.topic)
        }
        assert set(doc.entities) <= home


def test_topic_terms_cluster(gen):
    """Docs of one topic share that topic's pseudo-term pool — the BM25
    signal the index's keyword paths rely on."""
    by_topic: dict[int, list[int]] = {}
    for i in range(CFG.n_docs):
        by_topic.setdefault(gen._topic_of(i), []).append(i)
    topic, members = next(
        (t, m) for t, m in by_topic.items() if len(m) >= 3
    )
    terms = set(gen.topic_terms[topic])
    for i in members[:3]:
        text = gen.doc(i).text.lower()
        assert any(t in text for t in terms)


def test_queries_deterministic_and_anchored(gen):
    qs = gen.queries()
    assert len(qs) == CFG.n_queries
    assert [q.text for q in qs] == [q.text for q in SynCorpus(CFG).queries()]
    for j, q in enumerate(qs[:8]):
        # the quoted topic term is a required keyword the query encoder picks up
        assert '"' in q.text
        assert 0 <= q.topic < CFG.n_topics
        if j % 2 == 0:  # even queries mention a home entity
            assert len(extract_entity_spans(q.text)) >= 1


def test_fit_sample_strided_and_bounded(gen):
    sample = gen.fit_sample(64)
    assert 0 < len(sample) <= 64
    assert sample[0] == gen.doc(0).text
    assert sample[-1] == gen.doc(CFG.n_docs - 1).text
    # oversampling clamps to the corpus
    assert len(gen.fit_sample(10**6)) == CFG.n_docs
