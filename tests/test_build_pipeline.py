"""Device-resident build pipeline: equivalence with the legacy host-driven
path, dispatch-count collapse, insert regression (reverse-neighbor bug)."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    BuildConfig,
    KnnConfig,
    PruneConfig,
    build_index,
    insert,
    nn_descent,
)
from repro.core.knn_graph import build_knn_graph, knn_recall, new_node_reverse
from repro.core.search import SearchParams, search
from repro.core.usms import PAD_IDX, PathWeights, weighted_query
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.kernels import ops
from repro.runtime import dispatch


def small_corpus(n=512, seed=0):
    return make_corpus(
        CorpusConfig(
            n_docs=n, n_queries=16, n_topics=16, d_dense=32,
            nnz_sparse=16, nnz_lexical=8, seed=seed,
        )
    )


CFG = BuildConfig(
    knn=KnnConfig(k=16, iters=4, node_chunk=256),
    prune=PruneConfig(degree=12, keyword_degree=6, node_chunk=128),
    path_refine_iters=2,
)


def _row_overlap(a: np.ndarray, b: np.ndarray) -> float:
    """Mean per-row Jaccard overlap of valid ids (empty == empty counts 1)."""

    def jac(r1, r2):
        s1, s2 = set(r1[r1 >= 0].tolist()), set(r2[r2 >= 0].tolist())
        return len(s1 & s2) / len(s1 | s2) if (s1 | s2) else 1.0

    return float(np.mean([jac(r1, r2) for r1, r2 in zip(a, b)]))


def test_nn_descent_matches_legacy():
    """The in-trace descent program reproduces the legacy chunk loop for the
    same (cfg, key): identical key chain, row-wise identical math."""
    corpus = small_corpus(n=300)  # not a multiple of node_chunk (padding path)
    cfg = KnnConfig(k=12, iters=3, node_chunk=128)
    key = jax.random.key(5)
    ids_new, sc_new = nn_descent(corpus.docs, cfg, key)
    ids_old, sc_old = build_knn_graph(corpus.docs, cfg, key)
    assert ids_new.shape == ids_old.shape
    assert _row_overlap(np.asarray(ids_new), np.asarray(ids_old)) > 0.98
    np.testing.assert_allclose(
        np.sort(np.asarray(sc_new), axis=1),
        np.sort(np.asarray(sc_old), axis=1),
        rtol=1e-4,
        atol=1e-4,
    )


def test_pipeline_build_matches_legacy_build():
    corpus = small_corpus()
    key = jax.random.key(0)
    with dispatch.track() as t_new:
        new = build_index(corpus.docs, CFG, key=key, pipeline=True)
    with dispatch.track() as t_old:
        old = build_index(corpus.docs, CFG, key=key, pipeline=False)

    # structural bit-compatibility: same shapes, same PAD contract
    for name in ("semantic_edges", "keyword_edges", "entry_points", "alive"):
        assert getattr(new, name).shape == getattr(old, name).shape, name
        assert getattr(new, name).dtype == getattr(old, name).dtype, name
    sem_new = np.asarray(new.semantic_edges)
    sem_old = np.asarray(old.semantic_edges)
    assert ((sem_new >= -1) & (sem_new < corpus.docs.n)).all()
    assert _row_overlap(sem_new, sem_old) > 0.9
    assert _row_overlap(np.asarray(new.keyword_edges), np.asarray(old.keyword_edges)) > 0.9
    np.testing.assert_allclose(
        np.asarray(new.self_ip), np.asarray(old.self_ip), rtol=1e-4
    )

    # the whole device-side build is >= 2x fewer dispatches (in fact, one)
    assert t_new.count * 2 <= t_old.count, (t_new.count, t_old.count)

    # retrieval quality within tolerance of the legacy path
    w = PathWeights.three_path()
    qw = weighted_query(corpus.queries, w)
    truth = np.asarray(jax.lax.top_k(ops.pairwise_scores_chunked(qw, corpus.docs), 10)[1])
    params = SearchParams(k=10, iters=32, pool_size=64)
    rec_new = recall_at_k(np.asarray(search(new, corpus.queries, w, params).ids), truth)
    rec_old = recall_at_k(np.asarray(search(old, corpus.queries, w, params).ids), truth)
    assert rec_new > rec_old - 0.03, (rec_new, rec_old)


def test_pipeline_knn_quality():
    corpus = small_corpus(n=256, seed=2)
    cfg = KnnConfig(k=16, iters=5, node_chunk=256)
    ids, _ = nn_descent(corpus.docs, cfg, jax.random.key(0))
    n = corpus.docs.n
    full = ops.pairwise_scores_chunked(corpus.docs, corpus.docs)
    full = full.at[jnp.arange(n), jnp.arange(n)].set(-jnp.inf)
    _, truth = jax.lax.top_k(full, 16)
    rec = knn_recall(ids, truth)
    assert rec > 0.80, f"pipeline NN-Descent recall too low: {rec}"


# ---------------------------------------------------------------------------
# insert through the pipeline
# ---------------------------------------------------------------------------


def test_new_node_reverse_regression():
    """Reverse edges of an insert batch: global candidate ids must not be
    misread as new-node rows. Old-corpus ids (< n_old) appear in the lists
    but must never produce reverse entries; new-node targets get exactly
    the sources that list them."""
    n_old = 100
    # 3 new nodes (global ids 100, 101, 102); lists hold mixed global ids
    merged = jnp.asarray(
        [
            [5, 101, 102, PAD_IDX],   # node 100 -> old 5, new 101, new 102
            [102, 7, PAD_IDX, PAD_IDX],  # node 101 -> new 102, old 7
            [0, 1, 2, PAD_IDX],       # node 102 -> old nodes only
        ],
        jnp.int32,
    )
    rev = np.asarray(new_node_reverse(merged, n_old, cap=4))
    as_set = lambda r: set(r[r >= 0].tolist())
    assert as_set(rev[0]) == set()            # nobody lists node 100
    assert as_set(rev[1]) == {100}            # node 100 lists 101
    assert as_set(rev[2]) == {100, 101}       # nodes 100 and 101 list 102
    # every returned source id is a NEW-node global id
    assert (rev[rev >= 0] >= n_old).all()


def test_insert_pipeline_invariants_and_quality():
    corpus = small_corpus()
    n = corpus.docs.n
    n_keep = n - 64
    base = build_index(corpus.docs[slice(0, n_keep)], CFG)
    with dispatch.track() as t:
        upd = insert(base, corpus.docs[slice(n_keep, n)], CFG)
    assert t.count <= 8, t.count  # search + descent(2) + fused insert program
    assert upd.n == n
    sem = np.asarray(upd.semantic_edges)
    assert sem.shape == (n, CFG.prune.degree)
    for u in range(n_keep, n):
        row = sem[u][sem[u] >= 0]
        assert len(set(row.tolist())) == len(row)
        assert u not in row.tolist()
        assert (row < n).all()
    # inserted region is searchable
    w = PathWeights.three_path()
    qw = weighted_query(corpus.queries, w)
    truth = np.asarray(jax.lax.top_k(ops.pairwise_scores_chunked(qw, corpus.docs), 10)[1])
    params = SearchParams(k=10, iters=40, pool_size=64)
    rec = recall_at_k(np.asarray(search(upd, corpus.queries, w, params).ids), truth)
    full = build_index(corpus.docs, CFG)
    rec_full = recall_at_k(np.asarray(search(full, corpus.queries, w, params).ids), truth)
    assert rec > rec_full - 0.1, (rec, rec_full)
