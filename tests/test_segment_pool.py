"""Segment pool: incremental compaction is O(grow segment), untouched
groups keep their compiled executables byte-identical, the size-tiered
merge policy bounds fragmentation, logical edges append incrementally into
a live grow segment, and a heterogeneous pool round-trips through the
atomic checkpoint layout."""

from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig
from repro.core.distributed import (
    build_segmented_index,
    place_segmented_index,
)
from repro.core.search import SearchParams
from repro.core.segment_pool import (
    SegmentPool,
    append_segment,
    build_pool_segment,
    live_counts,
    mark_deleted_pool,
    pool_placement,
    remove_segments,
    resolve_global_ids_pool,
)
from repro.core.usms import PathWeights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.runtime import dispatch
from repro.serving.batcher import BatcherConfig
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig
from repro.serving.segment_router import RouterConfig, SegmentRouter

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=12, iters=3, node_chunk=512),
    prune=PruneConfig(degree=12, keyword_degree=4, node_chunk=256),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=8, iters=16, pool_size=48)
W = PathWeights.make(1.0, 1.0, 1.0)
N_SEALED = 320


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=448, n_queries=16, n_topics=12, d_dense=24,
                     nnz_sparse=10, nnz_lexical=8, seed=37)
    )


@pytest.fixture(scope="module")
def sealed(corpus):
    return build_segmented_index(corpus.docs[:N_SEALED], 1, BUILD_CFG)


def _service(sealed, **router_kw):
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seg = place_segmented_index(sealed, mesh)
    svc = HybridSearchService(
        seg, PARAMS,
        ServiceConfig(batcher=BatcherConfig(
            flush_size=4, max_batch=4, flush_deadline_s=60.0)),
        mesh=mesh,
    )
    router_kw.setdefault("seal_threshold", 10**9)
    router = SegmentRouter(
        svc, BUILD_CFG,
        RouterConfig(compaction="incremental", **router_kw),
    )
    return svc, router


def _probe(corpus, i):
    return jax.tree.map(lambda a: a[i:i + 1], corpus.docs)


# ---------------------------------------------------------------------------
# pool data structure
# ---------------------------------------------------------------------------


def test_pool_wrap_resolve_and_tombstones(corpus, sealed):
    pool = SegmentPool.from_segmented(sealed)
    assert pool.n_groups == 1 and pool.n_segments == 1
    seg1 = build_pool_segment(
        corpus.docs[N_SEALED:N_SEALED + 12],
        np.arange(N_SEALED, N_SEALED + 12), BUILD_CFG, capacity=16,
    )
    pool, touched = append_segment(pool, seg1)
    assert pool.n_groups == 2 and touched == 1
    assert pool.capacities == (N_SEALED, 16)

    grp, seg, loc = resolve_global_ids_pool(
        pool, [0, N_SEALED + 5, N_SEALED + 12, 10**6]
    )
    np.testing.assert_array_equal(grp, [0, 1, -1, -1])
    assert loc[1] == 5  # pool-segment rows are in insertion order

    pool = mark_deleted_pool(pool, [3, N_SEALED + 5])
    assert sum(lc[3] for lc in live_counts(pool)) == N_SEALED + 12 - 2
    # unknown ids are ignored, shapes unchanged
    pool2 = mark_deleted_pool(pool, [10**6])
    assert pool2.capacities == pool.capacities


def test_append_stacks_same_shape_segments(corpus, sealed):
    pool = SegmentPool.from_segmented(sealed)
    a = build_pool_segment(
        corpus.docs[N_SEALED:N_SEALED + 10],
        np.arange(N_SEALED, N_SEALED + 10), BUILD_CFG, capacity=16,
    )
    b = build_pool_segment(
        corpus.docs[N_SEALED + 10:N_SEALED + 24],
        np.arange(N_SEALED + 10, N_SEALED + 24), BUILD_CFG, capacity=16,
    )
    pool, g1 = append_segment(pool, a)
    pool, g2 = append_segment(pool, b)
    assert g1 == g2 == 1  # same 16-capacity shape bucket
    assert pool.groups[1].n_segments == 2
    assert pool.n_segments == 3

    pool = remove_segments(pool, [(1, 0)])
    assert pool.groups[1].n_segments == 1
    grp, _, _ = resolve_global_ids_pool(pool, [N_SEALED + 3, N_SEALED + 15])
    assert grp[0] == -1 and grp[1] == 1  # a's docs gone, b's remain


def test_build_pool_segment_validations(corpus):
    with pytest.raises(ValueError, match="capacity"):
        build_pool_segment(
            corpus.docs[:8], np.arange(8), BUILD_CFG, capacity=4
        )
    with pytest.raises(ValueError, match="global_ids"):
        build_pool_segment(corpus.docs[:8], np.arange(7), BUILD_CFG)


def test_pool_placement_many_per_device(sealed, corpus):
    pool = SegmentPool.from_segmented(sealed)
    seg1 = build_pool_segment(
        corpus.docs[N_SEALED:N_SEALED + 8],
        np.arange(N_SEALED, N_SEALED + 8), BUILD_CFG,
    )
    pool, _ = append_segment(pool, seg1)
    placements = pool_placement(pool, mesh=None)
    assert [p.n_segments for p in placements] == [1, 1]
    # off-mesh everything is local/replicated
    assert not any(p.sharded for p in placements)
    assert placements[0].capacity == N_SEALED


# ---------------------------------------------------------------------------
# the acceptance criterion: O(grow) compaction + executable survival
# ---------------------------------------------------------------------------


def test_compact_incremental_is_o_grow_and_preserves_executables(
    corpus, sealed
):
    """`compact_incremental` rebuilds ONLY the grow segment's rows (the
    dispatch.build_rows work counter grows by the grow size, not the corpus
    size) and every sealed-segment AOT executable survives cache-identical;
    a full seal_and_compact rebuilds O(corpus) by contrast."""
    svc, router = _service(sealed)
    svc.search(corpus.queries[:4], W, k=5)  # warm the sealed executable
    warm = dict(svc.executable_cache)
    assert warm

    grow_n = 24
    svc.insert(corpus.docs[N_SEALED:N_SEALED + grow_n])
    svc.search(corpus.queries[:4], W, k=5)

    rows0 = dispatch.build_rows()
    router.compact_incremental()
    built = dispatch.build_rows() - rows0
    assert built == grow_n, (
        f"incremental compaction rebuilt {built} rows for a {grow_n}-doc "
        f"grow segment — it must scale with the grow size, not the "
        f"{N_SEALED}-doc corpus"
    )
    assert router.stats.incremental_compactions == 1
    assert svc.grow_index is None
    assert router.pool is not None and router.pool.n_groups == 2

    # sealed executables: same keys, SAME objects — not recompiles
    for k, exe in warm.items():
        assert svc.executable_cache.get(k) is exe, f"evicted/replaced: {k}"

    # both old and newly-sealed docs remain reachable under original ids
    res = svc.search(_probe(corpus, 7), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == 7
    res = svc.search(_probe(corpus, N_SEALED + 7), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 7
    # ... and the warm sealed executable is STILL untouched after reads
    for k, exe in warm.items():
        assert svc.executable_cache.get(k) is exe

    # contrast: the full rebuild is O(corpus)
    svc.insert(corpus.docs[N_SEALED + grow_n:N_SEALED + 2 * grow_n])
    rows1 = dispatch.build_rows()
    router.seal_and_compact()
    full_built = dispatch.build_rows() - rows1
    assert full_built >= N_SEALED + grow_n  # every surviving row rebuilt


def test_compact_incremental_drops_grow_tombstones(corpus, sealed):
    """Grow tombstones are reclaimed at seal; sealed tombstones survive as
    tombstones (their reclamation belongs to merge/full rebuild) but never
    surface in results."""
    svc, router = _service(sealed)
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])
    svc.mark_deleted([N_SEALED + 3, 11])  # one grow, one sealed
    router.compact_incremental()

    grp, _, _ = resolve_global_ids_pool(
        router.pool, [N_SEALED + 3, N_SEALED + 4, 11]
    )
    assert grp[0] == -1  # grow tombstone physically gone
    assert grp[1] >= 0
    assert grp[2] >= 0  # sealed tombstone still occupies its row...
    res = svc.search(_probe(corpus, 11), W, k=5)
    assert 11 not in np.asarray(res.ids)[0]  # ...but is never returned
    res = svc.search(_probe(corpus, N_SEALED + 3), W, k=5)
    assert N_SEALED + 3 not in np.asarray(res.ids)[0]


def test_compact_incremental_empty_and_all_dead_grow(corpus, sealed):
    svc, router = _service(sealed)
    v0 = svc.snapshot_version
    assert router.compact_incremental() == v0  # no grow: no-op
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 8])
    svc.mark_deleted(list(range(N_SEALED, N_SEALED + 8)))
    router.compact_incremental()  # all grow docs dead: grow just dropped
    assert svc.grow_index is None
    assert router.pool is None or router.pool.n_groups == 1


def test_auto_compact_incremental_on_threshold(corpus, sealed):
    svc, router = _service(sealed, seal_threshold=24, auto_compact=True)
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])
    assert router.stats.compactions == 0
    svc.insert(corpus.docs[N_SEALED + 16:N_SEALED + 32])
    assert router.stats.incremental_compactions == 1
    assert svc.grow_index is None
    res = svc.search(_probe(corpus, N_SEALED + 20), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 20


# ---------------------------------------------------------------------------
# merge policy
# ---------------------------------------------------------------------------


def test_merge_segments_coalesces_and_reclaims(corpus, sealed):
    svc, router = _service(sealed, auto_merge=False)
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])
    router.compact_incremental()
    svc.insert(corpus.docs[N_SEALED + 16:N_SEALED + 32])
    router.compact_incremental()
    pool = router.pool
    assert pool.n_segments == 3
    victim = N_SEALED + 5
    svc.mark_deleted([victim])  # tombstone inside a pooled segment

    segs = pool.segments()
    router.merge_segments(segs[-2], segs[-1])
    assert router.stats.merges == 1
    pool = router.pool
    assert pool.n_segments == 2
    # merged capacity covers both segments' live docs at pow2
    assert 32 in pool.capacities
    # the tombstone was physically reclaimed by the merge
    grp, _, _ = resolve_global_ids_pool(pool, [victim])
    assert grp[0] == -1
    res = svc.search(_probe(corpus, victim), W, k=5)
    assert victim not in np.asarray(res.ids)[0]
    # survivors of both merged segments stay reachable
    for doc in (N_SEALED + 2, N_SEALED + 30):
        res = svc.search(_probe(corpus, doc), W, k=5)
        assert int(np.asarray(res.ids)[0, 0]) == doc

    with pytest.raises(ValueError):
        router.merge_segments((0, 0), (0, 0))
    with pytest.raises(ValueError):
        router.merge_segments((0, 0), (9, 9))


def test_size_tier_merge_invariant(corpus, sealed):
    """With tier_fanout=2, a third same-tier segment triggers a merge; the
    pool never holds more than tier_fanout segments per pow2 tier."""
    svc, router = _service(sealed, tier_fanout=2, auto_merge=True)
    for b in range(4):
        lo = N_SEALED + 16 * b
        svc.insert(corpus.docs[lo:lo + 16])
        router.compact_incremental()
        # merges run on the background worker by default; the invariant
        # holds once the notified policy run drains
        router.wait_merges()
        tiers: dict[int, int] = {}
        for _, _, cap, _ in live_counts(router.pool):
            t = max(cap, 1).bit_length()
            tiers[t] = tiers.get(t, 0) + 1
        assert all(v <= 2 for v in tiers.values()), tiers
    assert router.stats.merges >= 1
    # every streamed doc is still reachable after the merge cascade
    for doc in (N_SEALED + 1, N_SEALED + 17, N_SEALED + 63):
        res = svc.search(_probe(corpus, doc), W, k=5)
        assert int(np.asarray(res.ids)[0, 0]) == doc
    # clean shutdown: stop_pump joins the router's merge worker too
    svc.stop_pump()
    assert router._merge_thread is None


def test_background_merge_equals_synchronous(corpus, sealed):
    """The background worker applies the SAME size-tiered policy as the
    synchronous path — after wait_merges the pool layouts agree."""
    svc_bg, router_bg = _service(sealed, tier_fanout=2, auto_merge=True)
    svc_sync, router_sync = _service(
        sealed, tier_fanout=2, auto_merge=True, background_merge=False
    )
    for b in range(3):
        lo = N_SEALED + 16 * b
        for svc, router in ((svc_bg, router_bg), (svc_sync, router_sync)):
            svc.insert(corpus.docs[lo:lo + 16])
            router.compact_incremental()
        router_bg.wait_merges()
    assert sorted(c for _, _, c, _ in live_counts(router_bg.pool)) == \
        sorted(c for _, _, c, _ in live_counts(router_sync.pool))
    assert router_bg.stats.merges == router_sync.stats.merges
    # stopping is idempotent and restart-safe: a new compaction after stop
    # re-spawns the worker
    router_bg.stop_merge_worker()
    router_bg.stop_merge_worker()
    svc_bg.insert(corpus.docs[N_SEALED + 48:N_SEALED + 64])
    router_bg.compact_incremental()
    router_bg.wait_merges()
    svc_bg.stop_pump()
    svc_sync.stop_pump()


def test_autocheckpoint_on_compaction(corpus, sealed, tmp_path):
    """RouterConfig.autocheckpoint_every wires save_pool into compaction:
    every Nth compaction persists a loadable pool snapshot."""
    from repro.checkpoint import load_pool

    ckpt_dir = tmp_path / "auto"
    svc, router = _service(
        sealed, auto_merge=False,
        autocheckpoint_every=2, autocheckpoint_dir=str(ckpt_dir),
    )
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])
    router.compact_incremental()
    assert router.stats.autocheckpoints == 0  # 1 compaction < every=2
    svc.insert(corpus.docs[N_SEALED + 16:N_SEALED + 32])
    router.compact_incremental()
    assert router.stats.autocheckpoints == 1
    loaded = load_pool(ckpt_dir)
    assert loaded.capacities == router.pool.capacities
    # the checkpoint is the full live pool, tombstones included
    assert sum(lc[3] for lc in live_counts(loaded)) == N_SEALED + 32


# ---------------------------------------------------------------------------
# incremental logical edges (satellite): entity paths appear BEFORE compaction
# ---------------------------------------------------------------------------


def test_grow_insert_appends_logical_edges_incrementally():
    kg_corpus = make_corpus(
        CorpusConfig(n_docs=256, n_queries=8, n_topics=8, d_dense=16,
                     nnz_sparse=8, nnz_lexical=6, seed=13)
    )
    n0 = 192
    sealed = build_segmented_index(
        kg_corpus.docs[:n0], 1, BUILD_CFG,
        kg_triplets=kg_corpus.kg.triplets,
        doc_entities=kg_corpus.doc_entities[:n0],
        n_entities=kg_corpus.kg.n_entities,
    )
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sealed = place_segmented_index(sealed, mesh)
    params = SearchParams(k=8, iters=16, pool_size=64, use_kg=True)
    svc = HybridSearchService(
        sealed, params,
        ServiceConfig(batcher=BatcherConfig(flush_size=2, max_batch=2)),
        mesh=mesh,
    )
    router = SegmentRouter(
        svc, BUILD_CFG,
        RouterConfig(seal_threshold=10**9, compaction="incremental"),
        kg_triplets=kg_corpus.kg.triplets,
        n_entities=kg_corpus.kg.n_entities,
    )
    w = PathWeights.make(0.2, 0.2, 0.2, kg=2.0)

    def entity_hits(doc):
        res = svc.search(
            kg_corpus.queries[:1], w,
            entities=np.asarray([[doc]], np.int32), k=8,
        )
        return np.asarray(res.ids)[0]

    # birth batch (has entities) — worked before this PR
    svc.insert(kg_corpus.docs[n0:n0 + 16],
               new_doc_entities=kg_corpus.doc_entities[n0:n0 + 16])
    assert 200 in entity_hits(200)

    # SECOND insert into the live grow segment: its entity paths must be
    # searchable IMMEDIATELY (previously deferred to compaction)
    svc.insert(kg_corpus.docs[n0 + 16:n0 + 32],
               new_doc_entities=kg_corpus.doc_entities[n0 + 16:n0 + 32])
    assert 220 in entity_hits(220), (
        "doc inserted into an already-born grow segment has no entity path "
        "before compaction"
    )

    # and they survive the incremental seal into the pool
    router.compact_incremental()
    assert svc.grow_index is None
    assert 220 in entity_hits(220)
    assert 100 in entity_hits(100)  # sealed path untouched


# ---------------------------------------------------------------------------
# persistence: heterogeneous pool round-trip
# ---------------------------------------------------------------------------


def test_pool_persistence_roundtrip(corpus, sealed, tmp_path):
    from repro.checkpoint import load_pool, save_pool

    svc, router = _service(sealed, auto_merge=False)
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])
    router.compact_incremental()
    svc.insert(corpus.docs[N_SEALED + 16:N_SEALED + 56])
    router.compact_incremental()
    svc.mark_deleted([5])
    pool = router.pool
    assert pool.n_groups >= 2  # genuinely heterogeneous capacities
    assert len(set(pool.capacities)) >= 2

    save_pool(tmp_path / "pool", pool)
    assert (tmp_path / "pool" / "step_0.done").exists()
    loaded = load_pool(tmp_path / "pool")
    assert loaded.n_groups == pool.n_groups
    assert loaded.capacities == pool.capacities
    for a, b in zip(jax.tree.leaves(pool), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # a reloaded pool serves searches identically (fresh service, no mesh)
    svc2 = HybridSearchService(
        loaded, PARAMS,
        ServiceConfig(batcher=BatcherConfig(flush_size=4, max_batch=4)),
    )
    r_orig = svc.search(corpus.queries[:4], W, k=5)
    r_load = svc2.search(corpus.queries[:4], W, k=5)
    np.testing.assert_array_equal(
        np.asarray(r_orig.ids), np.asarray(r_load.ids)
    )

    # second save = fresh committed step; load still sees the latest
    save_pool(tmp_path / "pool", loaded)
    assert (tmp_path / "pool" / "step_1.done").exists()
    again = load_pool(tmp_path / "pool")
    assert again.capacities == pool.capacities


def test_load_pool_rejects_non_pool_checkpoint(tmp_path):
    from repro.checkpoint import load_pool

    with pytest.raises(FileNotFoundError):
        load_pool(tmp_path / "nope")
