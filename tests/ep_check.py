"""Subprocess check: moe_impl=ep_manual == moe_impl=gspmd numerically
(8 fake devices, (2,2,2) pod/data/model mesh)."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.models.layers import ShardCtx


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    base = dataclasses.replace(
        get_smoke_config("kimi-k2-1t-a32b"),
        dtype="float32",
        capacity_factor=8.0,  # no-drop so both dispatch schemes agree exactly
        n_experts=8,
    )
    ep = dataclasses.replace(base, moe_impl="ep_manual")
    params = tfm.init_params(jax.random.key(0), base)
    # make routing decisive: near-tie top-k picks can flip between the two
    # implementations' (numerically different) router matmuls, which is
    # selection instability, not an EP bug — widen the logit gaps
    params["layers"]["moe"]["router"] = params["layers"]["moe"]["router"] * 10.0
    specs = tfm.param_specs(base, ShardCtx(model_size=2))
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    params = jax.tree.map(lambda a, sh: jax.device_put(a, sh), params, shardings)
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, base.vocab, jnp.int32)
    tokens = jax.device_put(tokens, NamedSharding(mesh, P(("pod", "data"), None)))

    with jax.set_mesh(mesh):
        out_g, _, _ = jax.jit(tfm.make_forward(base, mesh.axis_names))(params, tokens)
        out_e, _, _ = jax.jit(tfm.make_forward(ep, mesh.axis_names))(params, tokens)
        np.testing.assert_allclose(
            np.asarray(out_g), np.asarray(out_e), rtol=2e-4, atol=2e-4
        )
        # gradients agree too. aux load-balancing loss is per-DP-shard in
        # ep_manual (the standard distributed-MoE semantics) vs global in the
        # GSPMD program — a documented semantic difference, excluded here to
        # isolate the dispatch path.
        tfm.AUX_LOSS_COEF = 0.0
        loss_g = tfm.make_loss_fn(base, mesh.axis_names)
        loss_e = tfm.make_loss_fn(ep, mesh.axis_names)
        g1 = jax.jit(jax.grad(loss_g))(params, {"tokens": tokens})
        g2 = jax.jit(jax.grad(loss_e))(params, {"tokens": tokens})
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            # top-k routing can flip on near-tie logits between the two
            # (numerically different) matmul partitionings — a property of
            # MoE top-k, not of the EP implementation. Require near-total
            # element agreement and a bounded worst case instead of exact
            # equality.
            aa, bb = np.asarray(a, np.float32), np.asarray(b, np.float32)
            close = np.isclose(aa, bb, rtol=1e-2, atol=1e-3)
            frac = close.mean()
            assert frac > 0.99, f"only {frac:.4f} of grad elements agree"
            assert np.abs(aa - bb).max() < 0.1
    print("EP_CHECK_PASS")


if __name__ == "__main__":
    main()
