"""Pallas flash attention vs naive oracle: shape/dtype sweep (fwd), gradient
check (bwd kernels), GQA head mapping, causal masking, and model-level
equivalence (naive vs flash configs produce the same logits/grads)."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, ref_attention

CASES = [
    # (B, H, KV, L, S, dk, dv, bq, bk)
    (1, 1, 1, 16, 16, 8, 8, 8, 8),
    (2, 4, 2, 64, 64, 32, 32, 32, 32),
    (1, 8, 2, 128, 128, 64, 64, 64, 32),  # GQA g=4, uneven blocks
    (2, 2, 2, 96, 96, 48, 32, 32, 48),  # dk != dv (MLA-style)
    (1, 4, 4, 64, 128, 32, 32, 64, 64),  # cross: S > L
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_matches_ref(case, causal, dtype):
    b, h, kv, l, s, dk, dv, bq, bk = case
    if causal and l != s:
        pytest.skip("causal assumes L == S here")
    rng = np.random.default_rng(sum(case))
    q = jnp.asarray(rng.normal(size=(b, h, l, dk)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kv, s, dk)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kv, s, dv)), dtype)
    out = flash_attention(q, k, v, causal, None, bq, bk, True)
    want = ref_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("case", CASES[:3])
def test_grads_match_ref(case):
    b, h, kv, l, s, dk, dv, bq, bk = case
    rng = np.random.default_rng(17)
    q = jnp.asarray(rng.normal(size=(b, h, l, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kv, s, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kv, s, dv)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(b, h, l, dv)), jnp.float32)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, bq, bk, True) * w)

    def loss_ref(q, k, v):
        return jnp.sum(ref_attention(q, k, v, causal=True) * w)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4,
            err_msg=f"d{name} mismatch",
        )


def test_model_level_flash_equals_naive():
    """Full model forward + grads with attn_impl=flash == naive."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm

    for arch in ("llama3.2-1b", "deepseek-v3-671b"):
        base = dataclasses.replace(
            get_smoke_config(arch), dtype="float32", capacity_factor=8.0
        )
        flash = dataclasses.replace(
            base, attn_impl="flash", flash_block_q=16, flash_block_k=16
        )
        params = tfm.init_params(jax.random.key(0), base)
        tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, base.vocab, jnp.int32)
        out_n, _, _ = jax.jit(tfm.make_forward(base))(params, tokens)
        out_f, _, _ = jax.jit(tfm.make_forward(flash))(params, tokens)
        np.testing.assert_allclose(
            np.asarray(out_n), np.asarray(out_f), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} flash != naive",
        )
        loss_n = tfm.make_loss_fn(base)
        loss_f = tfm.make_loss_fn(flash)
        batch = {"tokens": tokens}
        g_n = jax.grad(loss_n)(params, batch)
        g_f = jax.grad(loss_f)(params, batch)
        for a, b in zip(jax.tree.leaves(g_n), jax.tree.leaves(g_f)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4,
                err_msg=f"{arch} grads differ",
            )
