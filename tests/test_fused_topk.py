"""Fused distance+top-k selection vs the lax.top_k oracle (DESIGN.md §10).

The fused kernel keeps the per-row running top-k in VMEM across candidate
tiles, so the (B, C) score matrix never reaches HBM. These tests pin the
contract: equal to score-then-``lax.top_k`` up to float summation order
(positions exactly, except across float-ulp ties), with PAD candidates,
k > live-candidate counts, non-multiple-of-C_TILE candidate counts, and the
pre-selection bias all covered. Kernel runs use interpret mode (CPU CI).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

try:  # property tests need hypothesis (a [test] extra); the rest run without
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.core.search import SearchParams, resolve_params
from repro.core.usms import PAD_IDX
from repro.kernels import ops, ref
from repro.kernels.fused_topk import K_LANE, NEG, k_pad
from tests.helpers import random_fused


def _case(seed, b, c, *, dd=16, ps=4, pf=2, pad_frac=0.0, with_bias=False):
    rng = np.random.default_rng(seed)
    q = random_fused(rng, (b,), d_dense=dd, ps=ps, pf=pf, vs=97, vf=31)
    cands = random_fused(rng, (b, c), d_dense=dd, ps=ps, pf=pf, vs=97, vf=31)
    cid = rng.integers(0, 10_000, size=(b, c)).astype(np.int32)
    cid[rng.random((b, c)) < pad_frac] = PAD_IDX
    bias = (
        jnp.asarray(rng.normal(size=(b, c)).astype(np.float32))
        if with_bias
        else None
    )
    return q, cands, jnp.asarray(cid), bias


def assert_topk_match(got, want):
    """Scores up to float summation order; positions exact except across
    float-ulp ties (both orders are then valid lax.top_k tie-breaks)."""
    gs, gi = np.asarray(got[0]), np.asarray(got[1])
    ws, wi = np.asarray(want[0]), np.asarray(want[1])
    np.testing.assert_allclose(gs, ws, rtol=1e-5, atol=1e-5)
    flip = gi != wi
    assert np.all(np.abs(gs - ws)[flip] <= 1e-4), (
        f"positions diverged beyond tie tolerance:\n{gi}\nvs\n{wi}"
    )


def test_k_pad_rule():
    assert k_pad(1) == K_LANE
    assert k_pad(K_LANE) == K_LANE
    assert k_pad(K_LANE + 1) == 2 * K_LANE
    with pytest.raises(ValueError):
        k_pad(0)


@pytest.mark.parametrize("c_tile", [8, 32])
@pytest.mark.parametrize(
    "b,c,k,pad_frac,with_bias",
    [
        (2, 40, 10, 0.0, False),
        (3, 33, 5, 0.3, True),  # C not a multiple of the tile
        (1, 7, 7, 0.5, False),
        (2, 130, 32, 0.1, True),
    ],
)
def test_kernel_matches_oracle(b, c, k, pad_frac, with_bias, c_tile):
    q, cands, cid, bias = _case(
        hash((b, c, k, c_tile)) % 2**31, b, c,
        pad_frac=pad_frac, with_bias=with_bias,
    )
    got = ops.fused_topk(
        q, cands, cid, k, bias=bias, c_tile=c_tile,
        use_kernel=True, interpret=True,
    )
    want = ref.fused_topk_ref(q, cands, cid, bias, k)
    assert_topk_match(got, want)


def test_oracle_matches_raw_lax_topk():
    """ref.fused_topk_ref really is score-then-lax.top_k on masked scores."""
    q, cands, cid, bias = _case(11, 2, 20, pad_frac=0.2, with_bias=True)
    scores = ref.hybrid_scores_ref(q, cands) + bias
    scores = jnp.where(cid >= 0, scores, NEG)
    top, pos = jax.lax.top_k(scores, 6)
    ws, wi = ref.fused_topk_ref(q, cands, cid, bias, 6)
    np.testing.assert_array_equal(np.asarray(ws), np.asarray(top))
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(pos))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_all_pad_candidates(use_kernel):
    """A round whose candidate tile is entirely PAD: every slot invalid."""
    q, cands, cid, _ = _case(3, 2, 16)
    cid = jnp.full_like(cid, PAD_IDX)
    s, p = ops.fused_topk(
        q, cands, cid, 4, c_tile=8,
        use_kernel=use_kernel, interpret=use_kernel,
    )
    assert np.all(np.asarray(s) == NEG)
    assert np.all(np.asarray(p) == PAD_IDX)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_k_exceeds_live_candidates(use_kernel):
    """k > live candidates: the tail holds (NEG, PAD_IDX) sentinels."""
    q, cands, cid, _ = _case(5, 2, 6)
    cid = cid.at[:, 3:].set(PAD_IDX)  # 3 live candidates per row
    k = 9
    s, p = ops.fused_topk(
        q, cands, cid, k, c_tile=8,
        use_kernel=use_kernel, interpret=use_kernel,
    )
    assert s.shape == (2, k) and p.shape == (2, k)
    assert np.all(np.asarray(s)[:, 3:] == NEG)
    assert np.all(np.asarray(p)[:, 3:] == PAD_IDX)
    assert np.all(np.asarray(p)[:, :3] >= 0)
    want = ref.fused_topk_ref(q, cands, cid, None, k)
    assert_topk_match((s, p), want)


def test_explicit_pad_candidates_are_inert():
    """Appending PAD candidates (the wrapper's non-multiple-of-C_TILE ELL
    padding: idx==PAD_IDX, val==0, cid==PAD_IDX) never changes the result."""
    q, cands, cid, bias = _case(7, 2, 10, with_bias=True)
    base = ops.fused_topk(
        q, cands, cid, 4, bias=bias, c_tile=8, use_kernel=True, interpret=True
    )
    grow = 6  # 10 -> 16, a full extra tile of explicit padding
    padded_cands = jax.tree.map(
        lambda a: jnp.pad(
            a,
            [(0, 0), (0, grow)] + [(0, 0)] * (a.ndim - 2),
            constant_values=PAD_IDX if a.dtype == jnp.int32 else 0,
        ),
        cands,
    )
    padded = ops.fused_topk(
        q,
        padded_cands,
        jnp.pad(cid, ((0, 0), (0, grow)), constant_values=PAD_IDX),
        4,
        bias=jnp.pad(bias, ((0, 0), (0, grow))),
        c_tile=8,
        use_kernel=True,
        interpret=True,
    )
    assert_topk_match(padded, base)


def test_bias_shifts_selection():
    """A huge bias on one candidate forces it to rank first; zero bias is a
    no-op vs the unbiased call."""
    q, cands, cid, _ = _case(9, 2, 12)
    bias = jnp.zeros((2, 12), jnp.float32).at[:, 5].set(1e6)
    s, p = ops.fused_topk(
        q, cands, cid, 3, bias=bias, c_tile=8, use_kernel=True, interpret=True
    )
    assert np.all(np.asarray(p)[:, 0] == 5)
    no_bias = ops.fused_topk(
        q, cands, cid, 3, c_tile=8, use_kernel=True, interpret=True
    )
    zero_bias = ops.fused_topk(
        q, cands, cid, 3, bias=jnp.zeros((2, 12), jnp.float32),
        c_tile=8, use_kernel=True, interpret=True,
    )
    assert_topk_match(zero_bias, no_bias)


def test_take_topk_roundtrip():
    """Positions resolve back to candidate ids/metadata; PAD -> fill."""
    q, cands, cid, _ = _case(13, 2, 20, pad_frac=0.6)
    s, p = ops.fused_topk(q, cands, cid, 8, c_tile=8, use_kernel=False)
    got_ids = np.asarray(ops.take_topk_ids(cid, p))
    pos = np.asarray(p)
    cid_np = np.asarray(cid)
    for b in range(2):
        for j in range(8):
            want = PAD_IDX if pos[b, j] < 0 else cid_np[b, pos[b, j]]
            assert got_ids[b, j] == want
    meta = jnp.arange(40, dtype=jnp.float32).reshape(2, 20)
    got_meta = np.asarray(ops.take_topk(meta, p, -7.0))
    assert np.all(got_meta[pos < 0] == -7.0)


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @st.composite
    def topk_case(draw):
        b = draw(st.integers(1, 3))
        c = draw(st.integers(1, 40))
        k = draw(st.integers(1, 12))
        pad_frac = draw(st.sampled_from([0.0, 0.25, 0.9, 1.0]))
        with_bias = draw(st.booleans())
        seed = draw(st.integers(0, 2**20))
        return _case(seed, b, c, pad_frac=pad_frac, with_bias=with_bias) + (k,)

    @settings(max_examples=25, deadline=None)
    @given(topk_case())
    def test_property_kernel_equals_lax_topk(case):
        """Fused kernel == score-then-lax.top_k up to tie order, across PAD
        density, k vs live-count, and non-multiple-of-C_TILE counts."""
        q, cands, cid, bias, k = case
        got = ops.fused_topk(
            q, cands, cid, k, bias=bias, c_tile=8,
            use_kernel=True, interpret=True,
        )
        want = ref.fused_topk_ref(q, cands, cid, bias, k)
        assert_topk_match(got, want)

    @settings(max_examples=15, deadline=None)
    @given(topk_case())
    def test_property_invalid_slots_are_sentinels(case):
        """Every returned slot is either a live candidate (pos valid, score
        finite) or the (NEG, PAD_IDX) sentinel — never a PAD candidate."""
        q, cands, cid, bias, k = case
        s, p = ops.fused_topk(
            q, cands, cid, k, bias=bias, c_tile=8,
            use_kernel=True, interpret=True,
        )
        s, p = np.asarray(s), np.asarray(p)
        cid_np = np.asarray(cid)
        live = p >= 0
        assert np.all(s[~live] == NEG)
        n_live = (cid_np >= 0).sum(axis=1)
        for b in range(p.shape[0]):
            assert live[b].sum() == min(k, n_live[b])
            assert np.all(cid_np[b, p[b, live[b]]] >= 0)


# ---------------------------------------------------------------------------
# Serving cache key (satellite: kernel mode must be a cache-key component)
# ---------------------------------------------------------------------------


def test_resolved_params_distinguish_kernel_mode():
    """HybridSearchService keys its AOT executable cache on
    (index key, bucket, params) — see hybrid_service._compile_cached callers.
    resolve_params must pin use_kernel to a concrete bool so kernel and
    oracle executables can never collide under one key."""
    auto = SearchParams(k=4, use_kernel=None)
    resolved = resolve_params(auto)
    assert resolved.use_kernel in (True, False)
    assert resolved.use_kernel == ops.resolve_use_kernel(None)
    on = dataclasses.replace(resolved, use_kernel=True)
    off = dataclasses.replace(resolved, use_kernel=False)
    assert on != off
    assert hash(("idx", 8, on)) != hash(("idx", 8, off))
    # resolving is idempotent and a no-op on already-concrete params
    assert resolve_params(resolved) == resolved
    assert resolve_params(on).use_kernel is True
    assert resolve_params(off).use_kernel is False
