"""Checkpoint/restart + fault tolerance: atomic commit, retention, restart
determinism under injected failures, straggler detection, elastic meshes."""

from __future__ import annotations

import dataclasses
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import all_steps
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as tfm
from repro.runtime.fault_tolerance import (
    FailureInjector,
    StragglerMonitor,
    elastic_mesh_shape,
    run_supervised,
)
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, make_train_step


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16), "d": jnp.int32(7)},
    }
    save_checkpoint(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    out = restore_checkpoint(tmp_path, 5, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_checkpoint_retention_and_markers(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert all_steps(tmp_path) == [4, 5]
    # stale tmp dirs are never visible as committed steps
    (tmp_path / ".tmp_junk").mkdir()
    assert all_steps(tmp_path) == [4, 5]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path, 1, {"w": jnp.zeros((5,))})


def _tiny_setup():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), vocab=128)
    tcfg = TrainConfig(opt=opt.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    step_fn = make_train_step(cfg, tcfg, None, None)

    def make_state():
        params = tfm.init_params(jax.random.key(0), cfg)
        return {"params": params, "opt": opt.init_opt_state(params, tcfg.opt)}

    return make_state, step_fn, pipe


def test_restart_determinism(tmp_path):
    """Crashing at steps 7 and 13 and restarting from checkpoints must yield
    the exact same loss trajectory as an uninterrupted run (deterministic
    data skip + bit-exact restore)."""
    make_state, step_fn, pipe = _tiny_setup()

    ref = run_supervised(
        n_steps=20, make_state=make_state, train_step=step_fn,
        batch_fn=pipe.batch, ckpt_dir=str(tmp_path / "ref"), ckpt_every=5,
    )
    assert ref.restarts == 0

    inj = FailureInjector(fail_at={7, 13})
    rep = run_supervised(
        n_steps=20, make_state=make_state, train_step=step_fn,
        batch_fn=pipe.batch, ckpt_dir=str(tmp_path / "crash"), ckpt_every=5,
        injector=inj,
    )
    assert rep.restarts == 2
    assert rep.steps_done == 20
    # compare the last losses (the crashed run replays some steps; its final
    # states must coincide with the reference)
    np.testing.assert_allclose(rep.losses[-1], ref.losses[-1], rtol=1e-6)
    np.testing.assert_allclose(rep.losses[-3], ref.losses[-3], rtol=1e-6)


def test_straggler_monitor():
    mon = StragglerMonitor(window=16, threshold=1.5)
    for step in range(16):
        for host in range(4):
            mon.record(host, 1.0 if host != 2 else 2.2)
    assert mon.stragglers() == [2]
    assert 0.9 < mon.p50() < 2.0


def test_elastic_mesh_shapes():
    # full fleet: 512 devices, TP=16 -> (2, 16, 16)
    shape, axes = elastic_mesh_shape(512, 16, pod_size=16)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lose one pod: 256 devices -> (16, 16) single-pod mesh
    shape, axes = elastic_mesh_shape(256, 16, pod_size=16)
    assert shape == (16, 16) and axes == ("data", "model")
    # lose half a pod's hosts: 384 devices -> (24, 16)
    shape, axes = elastic_mesh_shape(384, 16)
    assert shape == (24, 16)
    with pytest.raises(AssertionError):
        elastic_mesh_shape(250, 16)


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint written under one mesh restores under a different mesh
    (runs in a subprocess with 8 fake devices)."""
    import os
    import pathlib
    import subprocess
    import sys

    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{repo / 'src'}:{repo}"
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
save_checkpoint(r"{tmp_path}", 3, {{"w": wa}})
shard_b = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
out = restore_checkpoint(r"{tmp_path}", 3, {{"w": w}}, shardings=shard_b)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
assert out["w"].sharding.spec == P("model", "data")
print("ELASTIC_OK")
"""
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert "ELASTIC_OK" in proc.stdout
