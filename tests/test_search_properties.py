"""Hypothesis property tests on search invariants: for random corpora and
random path weights, results are sorted, unique, valid, and monotone in
search effort."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights, weighted_query
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.kernels import ops


@pytest.fixture(scope="module")
def small_index():
    corpus = make_corpus(
        CorpusConfig(n_docs=512, n_queries=8, n_topics=16, d_dense=32,
                     nnz_sparse=12, nnz_lexical=8, seed=23)
    )
    index = build_index(
        corpus.docs,
        BuildConfig(
            knn=KnnConfig(k=16, iters=4, node_chunk=512),
            prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=256),
            path_refine_iters=1,
        ),
    )
    return corpus, index


@settings(max_examples=12, deadline=None)
@given(
    st.floats(0.0, 2.0), st.floats(0.0, 2.0), st.floats(0.0, 2.0),
    st.sampled_from([1, 2, 4]),
)
def test_property_results_valid_for_any_weights(small_index, wd, ws, wf, expand):
    corpus, index = small_index
    if wd + ws + wf == 0.0:
        wd = 1.0
    w = PathWeights.make(wd, ws, wf)
    params = SearchParams(k=10, iters=24 // expand, pool_size=48, expand=expand)
    res = search(index, corpus.queries, w, params)
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    n = corpus.docs.n
    for row_i, row_s in zip(ids, scores):
        valid = row_i[row_i >= 0]
        # in-range, unique
        assert (valid < n).all()
        assert len(set(valid.tolist())) == len(valid)
        # sorted descending among valid entries
        vs = row_s[row_i >= 0]
        assert (np.diff(vs) <= 1e-5).all()


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**20))
def test_property_scores_are_true_hybrid_scores(small_index, seed):
    """Returned scores equal the hybrid score of the returned doc (no KG)."""
    corpus, index = small_index
    rng = np.random.default_rng(seed)
    w = PathWeights.make(*rng.uniform(0.1, 1.5, size=3))
    params = SearchParams(k=5, iters=24, pool_size=48)
    res = search(index, corpus.queries, w, params)
    qw = weighted_query(corpus.queries, w)
    want = ops.hybrid_scores_vs_ids(qw, corpus.docs, res.ids)
    got = np.asarray(res.scores)
    mask = np.asarray(res.ids) >= 0
    np.testing.assert_allclose(
        got[mask], np.asarray(want)[mask], rtol=1e-4, atol=1e-4
    )


def test_more_effort_never_hurts_much(small_index):
    """Recall is (weakly) monotone in search effort."""
    corpus, index = small_index
    w = PathWeights.three_path()
    qw = weighted_query(corpus.queries, w)
    truth = jax.lax.top_k(ops.pairwise_scores_chunked(qw, corpus.docs), 10)[1]
    recs = []
    for iters, pool in [(8, 32), (24, 48), (48, 64)]:
        res = search(index, corpus.queries, w, SearchParams(k=10, iters=iters, pool_size=pool))
        recs.append(recall_at_k(np.asarray(res.ids), np.asarray(truth)))
    assert recs[1] >= recs[0] - 0.02
    assert recs[2] >= recs[1] - 0.02
