"""Hypothesis property tests for the graph primitives the build pipeline is
made of: reverse_neighbors, dedup_mask, unique_take."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.knn_graph import dedup_mask, reverse_neighbors
from repro.core.pruning import unique_take
from repro.core.usms import PAD_IDX


@st.composite
def neighbor_tables(draw):
    n = draw(st.integers(2, 24))
    k = draw(st.integers(1, 6))
    rows = draw(
        st.lists(
            st.lists(
                st.one_of(st.integers(0, 1_000_000), st.just(PAD_IDX)),
                min_size=k,
                max_size=k,
            ),
            min_size=n,
            max_size=n,
        )
    )
    arr = np.asarray(rows, np.int32)
    arr = np.where((arr >= 0) & (arr < n), arr, PAD_IDX)
    # contract: neighbor lists hold unique ids per row (true by construction
    # in every caller — _merge_topk dedups); mask repeats to PAD
    for r in range(n):
        seen: set = set()
        for c in range(k):
            if arr[r, c] in seen:
                arr[r, c] = PAD_IDX
            else:
                seen.add(int(arr[r, c]))
    return arr


@settings(max_examples=60, deadline=None)
@given(neighbor_tables(), st.integers(1, 8))
def test_reverse_neighbors_properties(nbrs, cap):
    n = nbrs.shape[0]
    rev = np.asarray(reverse_neighbors(jnp.asarray(nbrs), cap))
    assert rev.shape == (n, cap)  # cap respected by construction
    for v in range(n):
        listed = rev[v][rev[v] >= 0]
        # soundness: every listed u really has v in N(u)
        for u in listed:
            assert v in nbrs[u], (u, v)
        # completeness up to the cap: if fewer sources than cap exist, all
        # of them are listed (no duplicates, nothing dropped)
        true_sources = {u for u in range(n) if v in nbrs[u]}
        assert len(set(listed.tolist())) == len(listed)
        if len(true_sources) <= cap:
            assert set(listed.tolist()) == true_sources


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.one_of(st.integers(0, 12), st.just(PAD_IDX)), min_size=1, max_size=40
    )
)
def test_dedup_mask_properties(ids):
    arr = np.asarray(ids, np.int32)
    mask = np.asarray(dedup_mask(jnp.asarray(arr)))
    # PAD entries are never kept
    assert not mask[arr == PAD_IDX].any()
    # exactly one keeper per distinct non-pad id
    for v in set(arr[arr >= 0].tolist()):
        assert mask[arr == v].sum() == 1


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.one_of(st.integers(0, 12), st.just(PAD_IDX)), min_size=1, max_size=24
    ),
    st.integers(1, 12),
)
def test_unique_take_properties(ids, width):
    arr = np.asarray(ids, np.int32)
    out = np.asarray(
        unique_take(jnp.asarray(arr), jnp.zeros(len(arr), jnp.float32), width)
    )
    assert out.shape == (width,)
    valid = out[out >= 0]
    # unique, and PAD never selected
    assert len(set(valid.tolist())) == len(valid)
    # stable first-occurrence order: output order matches first appearance
    distinct = []
    for v in arr:
        if v >= 0 and v not in distinct:
            distinct.append(int(v))
    assert valid.tolist() == distinct[: len(valid)]
    # pads only at the tail, and only when ids ran out
    n_valid = len(valid)
    assert (out[n_valid:] == PAD_IDX).all()
    assert n_valid == min(len(distinct), width)
